"""Durable runs: write-ahead journal + coordinated snapshots + resume
(DESIGN.md §14).

``REPRO_DURABILITY=journal`` (or ``FLConfig.durability="journal"``)
arms a :class:`~repro.durability.manager.DurabilityManager` on the
engine: every protocol event is journaled before its effects become
visible, and a coordinated multi-plane snapshot is written at round
boundaries. A run killed at *any* event boundary resumes via
:func:`resume_durable` — restore the newest valid snapshot, re-execute
deterministically, validate the re-emitted records against the journal
tail — and continues bit-identically to the uncrashed run.

The off path (default) constructs nothing, draws no RNG, and leaves
every pre-existing golden trace byte-identical.
"""
from __future__ import annotations

import os

from repro.core.journal import JOURNAL_NAME, Journal
from repro.core.services import (FLConfig, resolve_durability,
                                 resolve_durability_sync)
from repro.durability.manager import (DurabilityManager, JournalDivergence,
                                      SimulatedCrash, config_digest)
from repro.durability.snapshot import (find_latest_snapshot, install_snapshot,
                                       list_snapshots, load_snapshot,
                                       validate_snapshot, write_snapshot)

__all__ = [
    "DurabilityManager", "Journal", "JournalDivergence", "SimulatedCrash",
    "config_digest", "find_latest_snapshot", "install_snapshot",
    "list_snapshots", "load_snapshot", "resolve_durability",
    "resolve_durability_sync", "resume_durable", "validate_snapshot",
    "write_snapshot",
]


def resume_durable(cfg: FLConfig, model, data, fleet):
    """Rebuild a crashed durable run from ``cfg.checkpoint_dir``.

    Sequence: truncate any torn journal tail back to the last
    consistent prefix; pick the newest valid snapshot whose journal
    record survives in that prefix (falling back to older snapshots,
    then to genesis); rebuild the engine on the snapshot's database and
    params; overwrite its live state; and arm the manager with the
    journal tail so deterministic re-execution is validated record for
    record before new appends continue."""
    from repro.core.scheduler import build_engine

    if resolve_durability(cfg.durability) != "journal":
        raise ValueError("resume_durable requires durability='journal'")
    if not cfg.checkpoint_dir:
        raise ValueError("resume_durable requires cfg.checkpoint_dir")
    root = cfg.checkpoint_dir
    jpath = os.path.join(root, JOURNAL_NAME)
    if not os.path.exists(jpath):
        # crashed before the first record (or never started): fresh run
        return build_engine(cfg, model, data, fleet)
    records, _ = Journal.truncate_to_consistent(jpath)
    if records and records[0]["k"] == "genesis":
        saved = records[0]["p"]["config"]
        if saved != config_digest(cfg):
            raise ValueError(
                "journal was written under a different experiment config "
                f"(digest {saved} != {config_digest(cfg)}); refusing to "
                "resume — point checkpoint_dir elsewhere or restore the "
                "original config")
    last_seq = records[-1]["q"] if records else -1
    snap = find_latest_snapshot(root, max_seq=last_seq)
    if snap is None:
        engine = build_engine(cfg, model, data, fleet)
        tail, next_seq = records, 0
    else:
        state, db, params = load_snapshot(snap.path)
        engine = build_engine(cfg, model, data, fleet, db=db,
                              init_params=params)
        install_snapshot(engine, state, snap.path)
        tail, next_seq = [r for r in records if r["q"] > snap.seq], snap.seq + 1
    engine.durability = DurabilityManager(engine, expected=tail,
                                          next_seq=next_seq)
    return engine
