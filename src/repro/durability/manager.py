"""Durability manager: journal hooks, crash injection, resume tail
validation (DESIGN.md §14).

The manager sits between the engines and the ``Journal``: every
protocol event (``Scheduler._dispatch`` / the legacy ``_emit``) and
every round boundary produces one journal record carrying the simulated
clock, the round, the event payload, and a cheap RNG/cursor fingerprint
(platform PCG64 position, traffic cursor, live recovery-timer count;
round markers add the selection-RNG position and the trainer PRNG key).

On resume the manager is armed with the journal tail past the restored
snapshot: re-executed appends are *validated* against the tail record
for record instead of being rewritten — any mismatch raises
``JournalDivergence`` rather than silently forking the trace — and
once the tail is exhausted, new records append as usual, leaving the
journal byte-identical to the uncrashed run's.

Crash injection (the chaos harness): ``crash_after=k`` kills the
process right after the k-th record is processed — ``raise`` unwinds
with ``SimulatedCrash`` for in-process fuzzing; ``sigkill`` delivers a
real ``SIGKILL`` for subprocess fuzzing. Both are reachable via the
``REPRO_CRASH_AFTER_EVENTS`` / ``REPRO_CRASH_MODE`` env knobs.
"""
from __future__ import annotations

import collections
import hashlib
import json
import os
import signal
from dataclasses import asdict
from typing import Optional, Sequence

import numpy as np

from repro.core.journal import JOURNAL_NAME, Journal, encode_event

#: FLConfig fields excluded from the genesis digest: identity of the
#: run, not of the experiment (a resume points at the same directory;
#: golden-vs-crash test runs point at different ones)
_DIGEST_EXCLUDE = ("checkpoint_dir", "checkpoint_every", "durability",
                   "durability_sync", "durability_snap_every")

_U64 = (1 << 64) - 1


def _live_timer_count(rt) -> int:
    """Recovery timers still armed — counted with the same liveness
    predicate the snapshot uses (stale heap entries awaiting their lazy
    ``_peek_timer`` purge are dead state, so a resumed heap legitimately
    omits them; the fingerprint must not see the difference)."""
    timers = getattr(rt, "_timers", None)
    if not timers:
        return 0
    from repro.core.services import Inflight
    n = 0
    for (_, _, round_, tag) in timers:
        if round_ < rt.db.round:
            continue
        if isinstance(tag, Inflight) and tag.done:
            continue
        n += 1
    return n


class SimulatedCrash(RuntimeError):
    """Raised by the in-process crash injector at the armed boundary."""


class JournalDivergence(RuntimeError):
    """A resumed run re-emitted a record that differs from the journal."""


def config_digest(cfg) -> str:
    d = {k: v for k, v in asdict(cfg).items() if k not in _DIGEST_EXCLUDE}
    return hashlib.sha1(
        json.dumps(d, sort_keys=True, default=str).encode()).hexdigest()


class DurabilityManager:
    def __init__(self, runtime, *, expected: Optional[Sequence[dict]] = None,
                 next_seq: int = 0):
        from repro.core.services import (resolve_durability_sync)
        cfg = runtime.cfg
        if not cfg.checkpoint_dir:
            raise ValueError(
                "durability='journal' requires cfg.checkpoint_dir (the "
                "journal and snapshots live there)")
        self.rt = runtime
        self.root = cfg.checkpoint_dir
        self.sync = resolve_durability_sync(cfg.durability_sync)
        self.snap_every = max(int(cfg.durability_snap_every), 1)
        self.journal = Journal(os.path.join(self.root, JOURNAL_NAME))
        self._expected = collections.deque(expected or ())
        self._seq = next_seq
        self.n_records = 0
        self.n_replayed = 0
        self.n_snapshots = 0
        self._config_digest = config_digest(cfg)
        ca = os.environ.get("REPRO_CRASH_AFTER_EVENTS", "")
        self.crash_after: Optional[int] = int(ca) if ca else None
        self.crash_mode = os.environ.get("REPRO_CRASH_MODE", "raise")

    # ------------------------------------------------------------ hooks
    def record_event(self, event) -> None:
        kind, payload = encode_event(event)
        self._record(kind, payload, round_=self.rt.db.round,
                     fsync=self.sync == "event")

    def record_marker(self, kind: str, round_: int) -> None:
        self._record(kind, {}, round_=round_, fsync=self.sync == "event")

    def on_round_closed(self) -> None:
        """Both engines call this right after ``db.round`` advances: the
        round-close marker always fsyncs (it is the boundary the "round"
        sync policy guarantees), and on the snapshot cadence the
        coordinated snapshot is written for this journal position."""
        rt = self.rt
        self._record("round_close", {}, round_=rt.db.round, fsync=True)
        if rt.db.round % self.snap_every == 0:
            from repro.durability.snapshot import write_snapshot
            if write_snapshot(rt, self.root, self._seq - 1):
                self.n_snapshots += 1

    def finish(self) -> None:
        self._record("run_end", {}, round_=self.rt.db.round, fsync=True)
        self.journal.close()

    # ---------------------------------------------------------- appends
    def _record(self, kind: str, payload: dict, *, round_: int,
                fsync: bool) -> None:
        if self._seq == 0 and kind != "genesis":
            self._record("genesis",
                         {"config": self._config_digest,
                          "engine": self.rt.engine_name, "version": 1},
                         round_=0, fsync=True)
        rec = {"q": self._seq, "k": kind, "t": self.rt.loop.now,
               "r": round_, "p": payload, "g": self._fingerprint(kind)}
        if self._expected:
            exp = self._expected.popleft()
            if exp != rec:
                raise JournalDivergence(
                    f"resume diverged from the journal at seq {self._seq}:\n"
                    f"  journal: {json.dumps(exp, sort_keys=True)}\n"
                    f"  replay:  {json.dumps(rec, sort_keys=True)}")
            self.n_replayed += 1
        else:
            self.journal.append(rec, fsync=fsync)
        self._seq += 1
        self.n_records += 1
        if self.crash_after is not None and self._seq >= self.crash_after:
            self._crash()

    def _crash(self) -> None:
        self.journal.flush()
        self.journal.close()
        if self.crash_mode == "sigkill":
            os.kill(os.getpid(), signal.SIGKILL)
        raise SimulatedCrash(
            f"injected crash after journal seq {self._seq - 1}")

    # ------------------------------------------------------ fingerprint
    def _fingerprint(self, kind: str) -> dict:
        """Cheap per-record RNG/cursor positions — the per-event
        divergence tripwire the tentpole asks for. Round markers add the
        selection RNG and the trainer PRNG key (one tiny device sync per
        round, not per event)."""
        rt = self.rt
        g = {"p": rt.platform._rng.bit_generator.state["state"]["state"] & _U64,
             "tc": rt._traffic_pos,
             "tm": _live_timer_count(rt)}
        if rt.platform.faults is not None:
            g["f"] = (rt.platform.faults._rng.bit_generator
                      .state["state"]["state"] & _U64)
        if kind in ("round_close", "run_end", "genesis"):
            g["s"] = rt.strategy.rng.bit_generator.state["state"]["state"] & _U64
            g["k"] = np.asarray(rt.trainer._key).tolist()
        return g

    # ---------------------------------------------------------- metrics
    def metrics(self) -> dict:
        return {
            "durability": "journal",
            "durability_sync": self.sync,
            "journal_records": self.n_records,
            "journal_replayed": self.n_replayed,
            "journal_bytes": self.journal.bytes_written,
            "journal_fsyncs": self.journal.n_fsyncs,
            "n_snapshots": self.n_snapshots,
        }
