"""Training driver: centralized (single-host) or federated training of any
assigned architecture, with checkpointing and restart.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --steps 20 \
        --smoke --batch 4 --seq 64 --ckpt-dir /tmp/ckpt

On a real TPU fleet the same step function lowers under the production mesh
(launch/dryrun.py proves every cell compiles); on this host use --smoke.
Federated mode (--federated) drives the Apodotiko controller instead
(see examples/train_fl_lm.py for the richer driver).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import get_config
from repro.models import build_model
from repro.optim import apply_updates, build_optimizer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    model = build_model(cfg)
    opt = build_optimizer(cfg.optimizer, cfg.learning_rate)
    rng = jax.random.PRNGKey(0)

    params, _ = model.init(rng)
    opt_state = opt.init(params)
    start_step = 0
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if mgr and args.resume and mgr.latest_step() is not None:
        state, extra, start_step = mgr.restore()
        params, opt_state = state["params"], state["opt_state"]
        params = jax.tree.map(jnp.asarray, params)
        opt_state = jax.tree.map(jnp.asarray, opt_state)
        print(f"resumed from step {start_step}")

    @jax.jit
    def train_step(params, opt_state, batch):
        (loss, _), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch)
        updates, opt_state = opt.update(grads, opt_state, params)
        return apply_updates(params, updates), opt_state, loss

    data_rng = np.random.default_rng(0)
    n_params = sum(int(x.size) for x in jax.tree.leaves(params))
    print(f"training {args.arch} ({n_params/1e6:.1f}M params, "
          f"{cfg.optimizer}) for {args.steps} steps")
    for step in range(start_step, args.steps):
        tokens = data_rng.integers(0, cfg.vocab_size,
                                   (args.batch, args.seq), dtype=np.int32)
        batch = {"tokens": jnp.asarray(tokens[:, :-1]),
                 "targets": jnp.asarray(tokens[:, 1:])}
        if cfg.family == "vlm":
            batch["patches"] = jnp.zeros((args.batch, cfg.n_patches,
                                          cfg.d_model), jnp.float32)
        if cfg.family == "encdec":
            batch["frames"] = jnp.asarray(
                data_rng.normal(size=(args.batch, args.seq - 1, cfg.d_model)),
                jnp.float32)
        t0 = time.time()
        params, opt_state, loss = train_step(params, opt_state, batch)
        loss = float(loss)
        print(f"  step {step:4d} loss={loss:.4f} ({time.time()-t0:.2f}s)")
        if mgr and (step + 1) % args.ckpt_every == 0:
            mgr.save(step + 1, {"params": jax.tree.map(np.asarray, params),
                                "opt_state": jax.tree.map(np.asarray, opt_state)},
                     extra={"arch": args.arch})
    if mgr:
        mgr.save(args.steps, {"params": jax.tree.map(np.asarray, params),
                              "opt_state": jax.tree.map(np.asarray, opt_state)},
                 extra={"arch": args.arch})
        print(f"checkpointed at {args.ckpt_dir}")


if __name__ == "__main__":
    main()
