"""Production mesh construction.

A function (never a module-level constant) so importing this module never
touches jax device state. Production target: TPU v5e pods, 256 chips per pod
as a (data=16, model=16) mesh; the multi-pod variant adds a leading
``pod`` axis (2 pods = 512 chips). The FL mapping treats ``pod`` as the
cohort axis (each pod trains a cohort member group; staleness-weighted
aggregation is a weighted psum over ``pod`` — see DESIGN.md §4).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_devices: int = 8):
    """Small mesh for unit tests (requires xla_force_host_platform_device_count)."""
    return jax.make_mesh((n_devices // 4, 4), ("data", "model"))


# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
