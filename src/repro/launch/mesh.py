"""Production mesh construction.

A function (never a module-level constant) so importing this module never
touches jax device state. Production target: TPU v5e pods, 256 chips per pod
as a (data=16, model=16) mesh; the multi-pod variant adds a leading
``pod`` axis (2 pods = 512 chips). The FL mapping treats ``pod`` as the
cohort axis (each pod trains a cohort member group; staleness-weighted
aggregation is a weighted psum over ``pod`` — see DESIGN.md §4).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def _debug_mesh_shape(n_devices: int) -> tuple[int, int]:
    """Largest valid (data, model) factorization of ``n_devices``.

    Prefers the widest model axis that divides n (4, then 3, then 2) and
    falls back to ``(n, 1)`` for primes and n < 2, so every positive
    device count yields a mesh covering exactly n devices. The old
    ``(n // 4, 4)`` arithmetic built a wrong-size mesh for n not
    divisible by 4 and an invalid zero-extent one for n < 4.
    """
    n = max(int(n_devices), 1)
    for model in (4, 3, 2):
        if n >= model and n % model == 0:
            return (n // model, model)
    return (n, 1)


def make_debug_mesh(n_devices: int = 8):
    """Small mesh for unit tests (requires xla_force_host_platform_device_count)."""
    return jax.make_mesh(_debug_mesh_shape(n_devices), ("data", "model"))


# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link
