import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell and extract memory / cost / collective analyses.

The two lines above MUST stay first: jax locks the device count on first
initialization, and the production meshes need 512 placeholder host devices.
(Only this entry point gets 512 devices — tests and benchmarks see 1.)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod both \
      --out results/dryrun.jsonl
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs.base import ARCH_IDS, SHAPES, get_config, shape_supported
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze
from repro.launch.steps import build_cell
from repro.models import build_model


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             overrides=None, rules_override=None, verbose: bool = True,
             roofline: bool = True, variant: str = "baseline"):
    """Lower+compile one cell; returns a result dict (or skip/error record).

    Two lowerings per single-pod cell:
      1. production program (scan over layers) -> proves compile-at-scale,
         gives memory_analysis;
      2. roofline program (unroll_layers=True) -> exact cost_analysis and
         collective bytes (XLA counts while-loop bodies once; unrolling
         removes the undercount). Multi-pod cells compile only (1) — the
         roofline table is single-pod per the assignment.
    """
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    ok, reason = shape_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": reason}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        cell = build_cell(arch, shape, mesh, overrides=overrides,
                          rules_override=rules_override, variant=variant)
        compiled_scan = cell.lower().compile()
        compile_s = time.time() - t0
        mem = compiled_scan.memory_analysis()
        if verbose:
            print(f"[{arch} x {shape_name} x {mesh_name}] compiled in "
                  f"{compile_s:.1f}s")
            print("  memory_analysis:", mem)
        total_params = sum(
            int(x.size) for x in jax.tree.leaves(cell.in_args[0]))
        rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
               "status": "ok", "kind": cell.kind,
               "total_params": total_params, "compile_s": compile_s}
        if not (roofline and not multi_pod):
            peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                    + mem.temp_size_in_bytes) if mem else 0
            rec["peak_memory_per_device"] = peak
            return rec
        # roofline lowering: unrolled layers, exact cost analysis
        t1 = time.time()
        ov = dict(overrides or {})
        ov["unroll_layers"] = True
        cell_u = build_cell(arch, shape, mesh, overrides=ov,
                            rules_override=rules_override, variant=variant)
        compiled_u = cell_u.lower().compile()
        unroll_compile_s = time.time() - t1
        hlo = compiled_u.as_text()
        roof = analyze(compiled_u, hlo, arch=arch, shape=shape,
                       mesh_name=mesh_name, n_devices=mesh.size,
                       cfg=cell.cfg, total_params=total_params,
                       kind=cell.kind, compile_s=compile_s,
                       mem_compiled=compiled_scan)
        rec.update(roof.to_dict())
        rec.update({"status": "ok", "kind": cell.kind,
                    "total_params": total_params, "variant": variant,
                    "unroll_compile_s": unroll_compile_s})
        if verbose:
            print(f"  roofline: compute={roof.compute_s*1e3:.2f}ms "
                  f"memory={roof.memory_s*1e3:.2f}ms "
                  f"collective={roof.collective_s*1e3:.2f}ms "
                  f"bottleneck={roof.bottleneck} "
                  f"useful_ratio={roof.useful_ratio:.2f} mfu={roof.mfu:.3f}")
        return rec
    except Exception as e:  # noqa: BLE001 — report, don't die mid-sweep
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:],
                "compile_s": time.time() - t0}


PROBE_DEPTHS = {
    # (L1, L2) reduced depths for cost extrapolation, respecting each arch's
    # structural period (hybrid attn_period=6, vlm cross period=4, deepseek
    # first dense layer, enc-dec symmetric stacks)
    "qwen3-1.7b": (4, 8), "granite-8b": (4, 8), "yi-6b": (4, 8),
    "qwen3-4b": (4, 8), "llama-3.2-vision-11b": (4, 8),
    "zamba2-2.7b": (6, 12), "deepseek-v2-lite-16b": (4, 7),
    "arctic-480b": (4, 8), "mamba2-370m": (4, 8),
    "seamless-m4t-large-v2": (4, 8),
}


def _depth_overrides(arch: str, L: int) -> dict:
    ov = {"n_layers": L, "unroll_layers": True}
    if arch == "seamless-m4t-large-v2":
        ov["enc_layers"] = L // 2
        ov["dec_layers"] = L // 2
    return ov


def run_cell_extrapolated(arch: str, shape_name: str, *, overrides=None,
                          verbose: bool = True):
    """Roofline costing via two reduced-depth unrolled lowerings + linear
    extrapolation in layer count (cost_analysis is exact for the unrolled
    program; per-layer cost is depth-independent for homogeneous stacks).
    Used where the full-depth unrolled compile is prohibitive on this host.
    The full-depth scan compile still proves compile-at-scale + memory."""
    from repro.launch.roofline import (
        Roofline, _cost_value, collective_bytes_per_device, model_flops,
        ssd_inner_scan_correction)

    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    ok, reason = shape_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": "16x16",
                "status": "skipped", "reason": reason}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=False)
        cell = build_cell(arch, shape, mesh, overrides=overrides)
        compiled_scan = cell.lower().compile()
        compile_s = time.time() - t0
        mem = compiled_scan.memory_analysis()
        total_params = sum(int(x.size) for x in jax.tree.leaves(cell.in_args[0]))
        L1, L2 = PROBE_DEPTHS[arch]
        probes = []
        t1 = time.time()
        for L in (L1, L2):
            ov = dict(overrides or {})
            ov.update(_depth_overrides(arch, L))
            c = build_cell(arch, shape, mesh, overrides=ov)
            comp = c.lower().compile()
            cost = comp.cost_analysis()
            probes.append({
                "L": L,
                "flops": _cost_value(cost, "flops"),
                "bytes": _cost_value(cost, "bytes accessed"),
                "coll": collective_bytes_per_device(comp.as_text(), mesh.size),
                "cfg": c.cfg,
            })
        unroll_compile_s = time.time() - t1

        def extrap(v1, v2):
            slope = (v2 - v1) / (L2 - L1)
            return max(v1 + slope * (cfg.n_layers - L1), 0.0)

        p1, p2 = probes
        # add ssd inner-scan corrections at probe depths before extrapolating
        f1 = p1["flops"] + ssd_inner_scan_correction(p1["cfg"], shape, cell.kind) / mesh.size
        f2 = p2["flops"] + ssd_inner_scan_correction(p2["cfg"], shape, cell.kind) / mesh.size
        flops = extrap(f1, f2)
        byts = extrap(p1["bytes"], p2["bytes"])
        coll_total = extrap(p1["coll"]["total"], p2["coll"]["total"])
        coll = {k: extrap(p1["coll"].get(k, 0.0), p2["coll"].get(k, 0.0))
                for k in p1["coll"]}
        peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes
                + mem.temp_size_in_bytes) if mem else 0
        roof = Roofline(
            arch=arch, shape=shape.name, mesh="16x16", n_devices=mesh.size,
            flops_per_device=flops, bytes_per_device=byts,
            coll_bytes_per_device=coll_total, coll_breakdown=coll,
            peak_memory_per_device=peak,
            model_flops_global=model_flops(cfg, shape, total_params),
            compile_s=compile_s)
        rec = roof.to_dict()
        rec.update({"status": "ok", "kind": cell.kind,
                    "total_params": total_params, "variant": "baseline",
                    "cost_mode": f"extrapolated[{L1},{L2}]",
                    "unroll_compile_s": unroll_compile_s})
        if verbose:
            print(f"[{arch} x {shape_name} x 16x16] scan compile "
                  f"{compile_s:.1f}s, probes {unroll_compile_s:.1f}s")
            print(f"  roofline(extrap): compute={roof.compute_s*1e3:.2f}ms "
                  f"memory={roof.memory_s*1e3:.2f}ms "
                  f"collective={roof.collective_s*1e3:.2f}ms "
                  f"bottleneck={roof.bottleneck} "
                  f"useful_ratio={roof.useful_ratio:.2f} mfu={roof.mfu:.3f}")
        return rec
    except Exception as e:  # noqa: BLE001
        return {"arch": arch, "shape": shape_name, "mesh": "16x16",
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:],
                "compile_s": time.time() - t0}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="architecture id (or --all)")
    ap.add_argument("--shape", default=None, choices=list(SHAPES),
                    help="input shape (default: all four)")
    ap.add_argument("--all", action="store_true", help="all 10 architectures")
    ap.add_argument("--multi-pod", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=None, help="append JSONL results here")
    ap.add_argument("--remat", default=None, choices=["on", "off"])
    ap.add_argument("--zero1", default=None, choices=["on", "off"])
    ap.add_argument("--optimizer", default=None)
    ap.add_argument("--no-roofline", action="store_true",
                    help="scan-compile proof only (skip the unrolled costing)")
    ap.add_argument("--variant", default="baseline",
                    help="cell variant (e.g. scatter_bf16 for fl_round)")
    ap.add_argument("--cost-mode", default="unroll",
                    choices=["unroll", "extrapolate"],
                    help="roofline costing: full unroll or 2-point depth "
                         "extrapolation (for archs whose full unrolled "
                         "compile is prohibitive on this host)")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.all or not args.arch else [args.arch]
    shapes = [args.shape] if args.shape else list(SHAPES)
    pods = {"single": [False], "multi": [True], "both": [False, True]}[args.multi_pod]

    overrides = {}
    if args.remat:
        overrides["remat"] = args.remat == "on"
    if args.zero1:
        overrides["zero1"] = args.zero1 == "on"
    if args.optimizer:
        overrides["optimizer"] = args.optimizer

    n_err = 0
    for arch in archs:
        for shape in shapes:
            for mp in pods:
                if args.cost_mode == "extrapolate" and not mp:
                    rec = run_cell_extrapolated(arch, shape,
                                                overrides=overrides or None)
                else:
                    rec = run_cell(arch, shape, multi_pod=mp,
                                   overrides=overrides or None,
                                   roofline=not args.no_roofline,
                                   variant=args.variant)
                if rec["status"] == "error":
                    n_err += 1
                    print(f"[{arch} x {shape} x "
                          f"{'2x16x16' if mp else '16x16'}] ERROR: "
                          f"{rec['error']}", file=sys.stderr)
                    print(rec.get("traceback", ""), file=sys.stderr)
                elif rec["status"] == "skipped":
                    print(f"[{arch} x {shape}] skipped: {rec['reason']}")
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(rec) + "\n")
    sys.exit(1 if n_err else 0)


if __name__ == "__main__":
    main()
