"""Step-function builders for training/serving under pjit + sharding specs.

Everything needed to lower one (arch x shape x mesh) cell:
  - ``build_cell``: abstract params/opt-state/batch/caches + their
    NamedShardings derived from the logical-axes trees;
  - train_step (fwd + bwd + optimizer), prefill (logits tail + cache build),
    serve_step (one decode token against a full cache).

Variants (used by the §Perf hillclimbs) are config transforms applied before
lowering — e.g. remat on/off, ZeRO-1 on/off, alternative rule tables.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig, get_config
from repro.models import build_model, input_specs
from repro.models.common import map_axes
from repro.optim import apply_updates, build_optimizer
from repro.sharding.rules import (
    DECODE_RULES,
    DEFAULT_RULES,
    LONGCTX_RULES,
    axis_rules,
    logical_spec,
    zero1_extend,
)

Pytree = Any


def rules_for(shape: ShapeConfig) -> dict:
    if shape.kind != "decode":
        return dict(DEFAULT_RULES)
    if shape.global_batch == 1:
        return dict(LONGCTX_RULES)
    return dict(DECODE_RULES)


def opt_state_axes(opt_name: str, axes_tree: Pytree) -> Pytree:
    """Logical axes for the optimizer state, mirroring the param axes."""
    if opt_name == "sgd":
        return {}
    if opt_name == "momentum":
        return {"m": axes_tree}
    if opt_name == "adam":
        return {"m": axes_tree, "v": axes_tree, "t": ()}
    if opt_name == "adafactor":
        def one(a):
            a = tuple(a)
            if len(a) >= 2:
                return {"row": a[:-1], "col": a[:-2] + a[-1:]}
            return {"v": a}
        return {"s": map_axes(axes_tree, one), "t": ()}
    raise ValueError(opt_name)


def specs_from_axes(axes_tree: Pytree, shapes_tree: Pytree, mesh: Mesh,
                    rules: dict, *, zero1: bool = False) -> Pytree:
    is_leaf = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)

    def one(names, arr):
        spec = logical_spec(names, arr.shape, mesh, rules)
        if zero1:
            spec = zero1_extend(spec, arr.shape, mesh, "data")
        return NamedSharding(mesh, spec)

    return jax.tree.map(one, axes_tree, shapes_tree, is_leaf=is_leaf)


@dataclass
class Cell:
    """One lowered (arch x shape x mesh) combination, pre-lowering."""

    arch: str
    cfg: ModelConfig
    shape: ShapeConfig
    mesh: Mesh
    rules: dict
    fn: Any                 # the function to jit
    in_args: tuple          # ShapeDtypeStructs
    in_shardings: tuple
    out_shardings: Any
    kind: str

    def lower(self):
        jitted = jax.jit(self.fn, in_shardings=self.in_shardings,
                         out_shardings=self.out_shardings)
        return jitted.lower(*self.in_args)


def _abstract_params(model, seed: int = 0):
    rng = jax.random.PRNGKey(seed)
    return jax.eval_shape(lambda r: model.init(r)[0], rng)


def _param_axes(cfg: ModelConfig):
    """Axes tree via a smoke-size init of the same family (tree topology and
    per-leaf logical axes are config-size independent)."""
    smoke = get_config(cfg.name, smoke=True)
    model = build_model(smoke)
    _, axes = model.init(jax.random.PRNGKey(0))
    return axes


def _build_cache(model, cfg: ModelConfig, B: int, S: int):
    if cfg.family == "encdec":
        return model.cache_struct(B, S, S)
    return model.cache_struct(B, S)


def build_cell(arch: str, shape: ShapeConfig, mesh: Mesh, *,
               overrides: Optional[dict] = None,
               rules_override: Optional[dict] = None,
               variant: str = "baseline") -> Cell:
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.with_(**overrides)
    model = build_model(cfg)
    rules = rules_override or rules_for(shape)
    params = _abstract_params(model)
    p_axes = _param_axes(cfg)
    p_shard = specs_from_axes(p_axes, params, mesh, rules)
    B, S = shape.global_batch, shape.seq_len

    if shape.kind == "flround":
        # The paper's aggregation step on the pod: K client updates (stacked
        # on a 'cohort' axis sharded over data) -> staleness-weighted global
        # model. The weighted reduce lowers to a psum over the data axis —
        # the FaaS aggregation function mapped onto TPU collectives.
        K = shape.global_batch
        rules = dict(rules)
        rules["cohort"] = "data"
        upd = jax.tree.map(lambda s: jax.ShapeDtypeStruct((K,) + s.shape,
                                                          s.dtype), params)
        u_axes = map_axes(p_axes, lambda a: ("cohort",) + tuple(a))
        u_shard = specs_from_axes(u_axes, upd, mesh, rules)
        w = jax.ShapeDtypeStruct((K,), jnp.float32)
        w_shard = NamedSharding(mesh, P())

        if variant == "scatter_bf16":
            # perf iteration #5: explicit shard_map reduction — local fp32
            # partial sums, then a bf16-wire psum over the data axis (half
            # the all-reduce bytes; precision equals the bf16 storage dtype
            # of the model anyway). Weights ride the same cohort sharding.
            from jax.experimental.shard_map import shard_map

            w_shard = NamedSharding(mesh, P("data"))
            leaves, treedef = jax.tree.flatten(upd)
            leaf_specs = [s.spec for s in jax.tree.leaves(u_shard)]
            out_specs = [s.spec for s in jax.tree.leaves(p_shard)]

            def fl_aggregate(updates, weights):
                lv = jax.tree.leaves(updates)

                def body(w_local, *xs):
                    outs = []
                    for x in xs:
                        wshape = (x.shape[0],) + (1,) * (x.ndim - 1)
                        part = jnp.sum(
                            x.astype(jnp.float32) * w_local.reshape(wshape)
                            .astype(jnp.float32), axis=0)
                        outs.append(jax.lax.psum(part.astype(jnp.bfloat16),
                                                 "data").astype(x.dtype))
                    return tuple(outs)

                outs = shard_map(
                    body, mesh=mesh,
                    in_specs=(P("data"),) + tuple(leaf_specs),
                    out_specs=tuple(out_specs),
                    check_rep=False)(weights, *lv)
                return jax.tree.unflatten(treedef, outs)
        else:
            def fl_aggregate(updates, weights):
                with axis_rules(mesh, rules):
                    wf = weights.astype(jnp.float32)

                    def one(x):
                        # broadcast-multiply + sum over the cohort axis: keeps
                        # every non-cohort dim's sharding intact and lowers the
                        # reduction to local partials + an all-reduce over the
                        # data axis (a rank-1 tensordot made GSPMD all-gather
                        # the model-sharded dims instead — perf iteration #3)
                        wshape = (x.shape[0],) + (1,) * (x.ndim - 1)
                        out = jnp.sum(x.astype(jnp.float32)
                                      * wf.reshape(wshape), axis=0)
                        return out.astype(x.dtype)

                    return jax.tree.map(one, updates)

        # output the aggregated model ZeRO-sharded over data as well: the
        # cohort reduction lowers to reduce-scatter instead of all-reduce
        # (each pod slice owns a shard of the new global; the next round's
        # broadcast is the all-gather the optimizer needed anyway)
        out_shard = (p_shard if variant == "scatter_bf16" else
                     specs_from_axes(p_axes, params, mesh, rules, zero1=True))
        return Cell(arch, cfg, shape, mesh, rules, fl_aggregate,
                    (upd, w), (u_shard, w_shard), out_shard, "flround")

    if shape.kind == "train":
        opt = build_optimizer(cfg.optimizer, cfg.learning_rate)
        opt_state = jax.eval_shape(opt.init, params)
        o_axes = opt_state_axes(cfg.optimizer, p_axes)
        o_shard = specs_from_axes(o_axes, opt_state, mesh, rules,
                                  zero1=cfg.zero1)
        batch, b_axes = input_specs(cfg, shape)
        b_shard = specs_from_axes(b_axes, batch, mesh, rules)

        def train_step(params, opt_state, batch):
            with axis_rules(mesh, rules):
                (loss, _), grads = jax.value_and_grad(
                    model.loss, has_aux=True)(params, batch)
                updates, opt_state = opt.update(grads, opt_state, params)
                params = apply_updates(params, updates)
            return params, opt_state, loss

        return Cell(arch, cfg, shape, mesh, rules, train_step,
                    (params, opt_state, batch),
                    (p_shard, o_shard, b_shard),
                    (p_shard, o_shard, NamedSharding(mesh, P())), "train")

    if shape.kind == "prefill":
        batch, b_axes = input_specs(cfg, shape)
        b_shard = specs_from_axes(b_axes, batch, mesh, rules)
        cache, c_axes = _build_cache(model, cfg, B, S)
        c_shard = specs_from_axes(c_axes, cache, mesh, rules)

        def prefill(params, batch):
            with axis_rules(mesh, rules):
                logits, caches, _ = model.apply(params, batch, make_cache=True)
                return logits[:, -1:, :], caches

        return Cell(arch, cfg, shape, mesh, rules, prefill,
                    (params, batch), (p_shard, b_shard),
                    (NamedSharding(mesh, P()), c_shard), "prefill")

    # decode: one new token against a cache of length S
    cache, c_axes = _build_cache(model, cfg, B, S)
    c_shard = specs_from_axes(c_axes, cache, mesh, rules)
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    t_shard = specs_from_axes(("batch", None), tokens, mesh, rules)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    scalar = NamedSharding(mesh, P())

    def serve_step(params, caches, tokens, pos):
        with axis_rules(mesh, rules):
            return model.decode_step(params, caches, tokens, pos)

    return Cell(arch, cfg, shape, mesh, rules, serve_step,
                (params, cache, tokens, pos),
                (p_shard, c_shard, t_shard, scalar),
                (scalar, c_shard), "decode")
