"""Roofline-term extraction from compiled dry-run artifacts.

Per (arch x shape x mesh):
  compute_s    = HLO_FLOPs_per_device / PEAK_FLOPS_BF16
  memory_s     = HLO_bytes_per_device / HBM_BW
  collective_s = collective_bytes_per_device / ICI_BW

``cost_analysis()`` FLOPs/bytes are per-partition (the compiled module is the
SPMD-partitioned program). Collective bytes are NOT in cost_analysis: we
parse the optimized HLO and sum payload bytes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, scaled by the
ring-transfer factor for the op's group size.

MODEL_FLOPS (analytic useful compute) = 6*N*D for dense training,
6*N_active*D for MoE; 2*N*D for pure forward (prefill/decode); attention
score/value FLOPs are added separately. The ratio MODEL_FLOPS/HLO_FLOPs
exposes remat recompute and dispatch waste.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Optional

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)
_SHAPE_RE = re.compile(r"(pred|s8|u8|s16|u16|f16|bf16|s32|u32|f32|s64|u64|f64|c64|c128)"
                       r"\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_per_device(hlo_text: str, n_devices: int) -> dict:
    """Sum effective bytes moved per device, by collective kind.

    Ring-transfer factors (payload = result bytes, group size g):
      all-reduce: 2 (g-1)/g, all-gather/reduce-scatter/all-to-all: (g-1)/g,
      collective-permute: 1.
    """
    out = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        payload = _shape_bytes(shape_str)
        # find the group size on the same line
        line_end = hlo_text.find("\n", m.start())
        line = hlo_text[m.start():line_end if line_end > 0 else None]
        g = n_devices
        gm = _GROUPS_RE.search(line)
        if gm:
            g = max(len(gm.group(1).split(",")), 1)
        else:
            gm2 = _GROUPS_IOTA_RE.search(line)
            if gm2:
                g = int(gm2.group(2))
        if g <= 1:
            continue
        factor = {"all-reduce": 2.0 * (g - 1) / g,
                  "all-gather": (g - 1) / g,
                  "reduce-scatter": (g - 1) / g,
                  "all-to-all": (g - 1) / g,
                  "collective-permute": 1.0}[kind]
        out[kind] += payload * factor
    out["total"] = sum(out.values())
    return out


def _cost_value(cost, key: str) -> float:
    if cost is None:
        return 0.0
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return float(cost.get(key, 0.0))


def active_params(cfg: ModelConfig, total_params: int) -> int:
    """Per-token active parameter count (MoE: only routed top-k + shared)."""
    if not cfg.n_experts:
        return total_params
    per_expert = 3 * cfg.d_model * cfg.moe_d_ff
    routed_total = cfg.n_experts * per_expert * (cfg.n_layers - cfg.first_dense_layers)
    active_routed = cfg.top_k * per_expert * (cfg.n_layers - cfg.first_dense_layers)
    return total_params - routed_total + active_routed


def model_flops(cfg: ModelConfig, shape: ShapeConfig, total_params: int) -> float:
    """Analytic useful FLOPs for the step (global, all devices)."""
    if shape.kind == "flround":
        # K-way weighted reduce: one multiply-add per stacked-update element
        # (total_params here counts the [K, ...] stacked input)
        return 2.0 * total_params
    n_act = active_params(cfg, total_params)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        base = 6.0 * n_act * tokens
        # causal attention scores+values: 6 * L * B * S^2 * H * hd (fwd+bwd),
        # halved for causality
        hd = cfg.hd()
        attn = 6.0 * cfg.n_layers * shape.global_batch * shape.seq_len ** 2 \
            * cfg.n_heads * hd * 0.5 if cfg.family not in ("ssm",) else 0.0
        return base + attn
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        hd = cfg.hd()
        attn = 2.0 * cfg.n_layers * shape.global_batch * shape.seq_len ** 2 \
            * cfg.n_heads * hd * 0.5 if cfg.family not in ("ssm",) else 0.0
        return 2.0 * n_act * tokens + attn
    # decode: one token per sequence
    tokens = shape.global_batch
    hd = cfg.hd()
    attn = 2.0 * cfg.n_layers * shape.global_batch * shape.seq_len \
        * cfg.n_heads * hd * 2.0 if cfg.family not in ("ssm",) else 0.0
    return 2.0 * n_act * tokens + attn


def ssd_inner_scan_correction(cfg: ModelConfig, shape: ShapeConfig,
                              kind: str) -> float:
    """Global FLOPs to add for the Mamba2 SSD *chunk* scan.

    The layer scan is unrolled for the roofline lowering, but the SSD
    intra-layer chunk scan stays a while loop (unrolling nc x L bodies is
    compile-prohibitive), so XLA counts its body once per layer instead of
    nc times. Analytic per-chunk-body FLOPs:
      y_diag: 2BQ^2(N + HP), states + y_off: 4BQNHP
    multiplied by (nc-1) missing iterations x mamba layers x pass multiplier
    (train with remat: fwd + recompute + 2x bwd = 4; prefill: 1).
    """
    if cfg.family not in ("ssm", "hybrid") or kind not in ("train", "prefill"):
        return 0.0
    S = shape.seq_len
    if S <= 0:
        return 0.0
    Q = min(cfg.ssm_chunk, S)
    nc = S // Q
    if nc <= 1:
        return 0.0
    B = shape.global_batch
    H = (cfg.ssm_expand * cfg.d_model) // cfg.ssm_headdim
    P = cfg.ssm_headdim
    N = cfg.ssm_state
    body = 2.0 * B * Q * Q * (N + H * P) + 4.0 * B * Q * N * H * P
    mult = 4.0 if kind == "train" else 1.0
    return body * (nc - 1) * cfg.n_layers * mult


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: dict
    peak_memory_per_device: float
    model_flops_global: float
    compile_s: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS_BF16

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step-time lower bound (perfect overlap -> max of terms)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        hlo_global = self.flops_per_device * self.n_devices
        return self.model_flops_global / hlo_global if hlo_global else 0.0

    @property
    def mfu(self) -> float:
        """Model FLOPs utilization at the roofline bound."""
        denom = self.step_time_s * self.n_devices * PEAK_FLOPS_BF16
        return self.model_flops_global / denom if denom else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "n_devices": self.n_devices,
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "coll_breakdown": self.coll_breakdown,
            "peak_memory_per_device": self.peak_memory_per_device,
            "model_flops_global": self.model_flops_global,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "bottleneck": self.bottleneck,
            "step_time_s": self.step_time_s, "useful_ratio": self.useful_ratio,
            "mfu": self.mfu, "compile_s": self.compile_s,
        }


def analyze(compiled, hlo_text: str, *, arch: str, shape: ShapeConfig,
            mesh_name: str, n_devices: int, cfg: ModelConfig,
            total_params: int, kind: str, compile_s: float = 0.0,
            mem_compiled=None) -> Roofline:
    cost = compiled.cost_analysis()
    flops = _cost_value(cost, "flops")
    byts = _cost_value(cost, "bytes accessed")
    flops += ssd_inner_scan_correction(cfg, shape, kind) / n_devices
    coll = collective_bytes_per_device(hlo_text, n_devices)
    mem = (mem_compiled or compiled).memory_analysis()
    peak = 0.0
    if mem is not None:
        peak = (getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0))
    return Roofline(
        arch=arch, shape=shape.name, mesh=mesh_name, n_devices=n_devices,
        flops_per_device=flops, bytes_per_device=byts,
        coll_bytes_per_device=coll["total"], coll_breakdown=coll,
        peak_memory_per_device=peak,
        model_flops_global=model_flops(cfg, shape, total_params),
        compile_s=compile_s)
