"""End-to-end driver: FEDERATED training of a transformer LM with Apodotiko.

    PYTHONPATH=src python examples/train_fl_lm.py               # container-sized
    PYTHONPATH=src python examples/train_fl_lm.py --full        # ~100M params

Every client is a serverless function holding a private token stream (its
"user corpus", a biased Markov source); the controller federates a
qwen3-family decoder LM across the heterogeneous fleet with CEF scoring +
async aggregation. This is the paper's technique applied to the assigned
architectures — any config id from repro.configs works via --arch.
"""
import argparse

import jax
import numpy as np

from repro.configs.base import get_config
from repro.core.controller import Controller, FLConfig
from repro.data.synthetic import FederatedDataset, _markov_chains
from repro.faas.hardware import paper_fleet
from repro.models.api import LMClientAdapter


def make_lm_federated_data(n_clients, vocab, seq_len, samples_per_client,
                           seed=0):
    rng = np.random.default_rng(seed)
    chains = _markov_chains(8, vocab, rng)
    roles = rng.integers(0, 8, n_clients)

    def sample(chain, count):
        seqs = np.zeros((count, seq_len + 1), np.int32)
        state = rng.integers(0, vocab, count)
        seqs[:, 0] = state
        for t in range(1, seq_len + 1):
            cum = chain[state].cumsum(axis=1)
            state = (rng.random((count, 1)) < cum).argmax(axis=1)
            seqs[:, t] = state
        return seqs

    card = rng.integers(samples_per_client // 2, samples_per_client + 1,
                        n_clients)
    n_max = int(card.max())
    X = np.zeros((n_clients, n_max, seq_len), np.int32)
    Y = np.full((n_clients, n_max, seq_len), -1, np.int32)
    for c in range(n_clients):
        seqs = sample(chains[roles[c]], int(card[c]))
        X[c, :card[c]] = seqs[:, :-1]
        Y[c, :card[c]] = seqs[:, 1:]
    ev = np.concatenate([sample(ch, 8) for ch in chains])
    return FederatedDataset(X, Y, card.astype(np.int64),
                            ev[:, :-1], ev[:, 1:], name="lm")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--full", action="store_true",
                    help="~100M-param config (needs real hardware)")
    ap.add_argument("--rounds", type=int, default=6)
    ap.add_argument("--clients", type=int, default=12)
    args = ap.parse_args()

    smoke = get_config(args.arch, smoke=True)
    if args.full:
        cfg_model = smoke.with_(n_layers=12, d_model=768, n_heads=12,
                                n_kv_heads=4, head_dim=64, d_ff=2048,
                                vocab_size=32_000)   # ~100M params
    else:
        cfg_model = smoke.with_(vocab_size=256)      # container-sized
    model = LMClientAdapter(cfg_model)
    n_params = sum(int(x.size) for x in jax.tree.leaves(
        jax.eval_shape(lambda r: model.init(r)[0], jax.random.PRNGKey(0))))
    print(f"federating {args.arch} ({cfg_model.n_layers}L, "
          f"{n_params/1e6:.1f}M params) over {args.clients} FaaS clients")

    data = make_lm_federated_data(args.clients, cfg_model.vocab_size,
                                  seq_len=32, samples_per_client=24)
    cfg = FLConfig(
        n_clients=args.clients, clients_per_round=max(4, args.clients // 3),
        rounds=args.rounds, strategy="apodotiko", concurrency_ratio=0.5,
        local_epochs=1, batch_size=4, optimizer="adam", lr=3e-4,
        base_step_time=2.0, seed=0)
    ctl = Controller(cfg, model, data, list(paper_fleet(args.clients)))
    m = ctl.run(progress=lambda log: print(
        f"  round {log.round:2d} sim_t={log.t_end:7.1f}s "
        f"token_acc={log.accuracy:.3f} aggregated={log.n_aggregated}"))
    print(f"done: {m['rounds']} rounds, token accuracy "
          f"{m['final_accuracy']:.3f}, cost ${m['total_cost_usd']:.3f}, "
          f"cold-start ratio {m['cold_start_ratio']:.2f}")


if __name__ == "__main__":
    main()
