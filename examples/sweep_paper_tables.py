"""Reproduce the paper's strategy-comparison tables with the sweep engine.

    PYTHONPATH=src python examples/sweep_paper_tables.py [preset]

Default preset is ``paper_mnist``: all six strategies (FedAvg, FedProx,
SCAFFOLD, FedLesScan, FedBuff, Apodotiko) on the paper's heterogeneous
65/25/10 hardware mix, rendered as three tables in the shape of the paper's
Tables IV-VI — time-to-accuracy/speedup, cost, and cold starts. Bench scale
by default (minutes); SWEEP_FULL=1 for the paper-scale grid. Other presets:
``paper_tables`` (all four datasets), ``cr_sweep``, ``hardware_scenarios``,
``staleness_ablation``, ``smoke`` — see ``repro.sweep.presets``.
"""
import sys

from repro.sweep import get_preset, run_sweep

TABLE_IV = ("dataset", "strategy", "target_acc", "time_to_target_s",
            "speedup_vs_fedavg", "final_acc", "best_acc")
TABLE_V = ("dataset", "strategy", "cost_usd", "cost_vs_fedavg",
           "n_invocations")
TABLE_VI = ("dataset", "strategy", "cold_starts", "cold_start_ratio",
            "cold_start_reduction_vs_fedavg")


def main() -> None:
    preset = sys.argv[1] if len(sys.argv) > 1 else "paper_mnist"
    spec = get_preset(preset)
    print(f"sweep {spec.name}: {spec.n_runs} runs", flush=True)
    table = run_sweep(spec, progress=lambda i, n, r, m: print(
        f"  [{i + 1}/{n}] {r.key}"
        + (f" FAILED: {m['error']}" if "error" in m else ""), flush=True))

    print("\n== Table IV: time to common accuracy & speedup vs FedAvg ==")
    print(table.to_markdown(columns=TABLE_IV))
    print("== Table V: FaaS cost ==")
    print(table.to_markdown(columns=TABLE_V))
    print("== Table VI: cold starts ==")
    print(table.to_markdown(columns=TABLE_VI))
    for s in sorted({r["strategy"] for r in table.rows} - {"fedavg"}):
        print(f"mean speedup vs fedavg [{s}]: {table.mean_speedup(s)}")


if __name__ == "__main__":
    main()
