"""Serving example: batched prefill + decode with any assigned architecture.

    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-370m --tokens 16

Runs the smoke-sized config of the chosen architecture: prefills a batch of
prompts, then decodes tokens autoregressively against the KV/SSM cache —
the same serve_step the multi-pod dry-run lowers at production shape.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.models import build_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    model = build_model(cfg)
    rng = jax.random.PRNGKey(0)
    params, _ = model.init(rng)
    B, P, T = args.batch, args.prompt_len, args.tokens
    cache_len = P + T
    prompts = jax.random.randint(rng, (B, P), 0, cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(rng, (B, cfg.n_patches, cfg.d_model))
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(rng, (B, P, cfg.d_model))

    t0 = time.time()
    logits, caches, _ = model.apply(batch=batch, params=params,
                                    make_cache=True, cache_len=cache_len)
    tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
    print(f"prefill {B}x{P} in {time.time()-t0:.2f}s "
          f"({args.arch}, {cfg.n_layers}L smoke config)")

    decode = jax.jit(model.decode_step)
    out = [tok]
    t0 = time.time()
    for i in range(T - 1):
        logits, caches = decode(params, caches, tok, jnp.int32(P + i))
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
        out.append(tok)
    seqs = jnp.concatenate(out, axis=1)
    dt = time.time() - t0
    print(f"decoded {T-1} steps x {B} seqs in {dt:.2f}s "
          f"({(T-1)*B/max(dt,1e-9):.1f} tok/s)")
    for b in range(min(B, 2)):
        print(f"  seq[{b}]: {seqs[b].tolist()}")


if __name__ == "__main__":
    main()
