"""Reproduces the paper's motivating Fig. 1: FedLesScan beats FedAvg on a
homogeneous fleet but collapses under hardware heterogeneity, while
Apodotiko's CEF scoring adapts.

    PYTHONPATH=src python examples/heterogeneous_cohort.py
"""
from repro.core.controller import Controller, FLConfig
from repro.data.synthetic import make_federated_dataset
from repro.faas.hardware import HARDWARE_PROFILES, paper_fleet
from repro.models.proxy_models import ProxyLSTM

N = 18


def fleet(scenario: str):
    if scenario == "homogeneous":
        return [HARDWARE_PROFILES["cpu2"]] * N
    if scenario == "two-tier":
        return [HARDWARE_PROFILES["cpu1"]] * 11 + [HARDWARE_PROFILES["cpu2"]] * 7
    return list(paper_fleet(N))  # cpu1/cpu2/gpu mix


def main() -> None:
    data = make_federated_dataset("shakespeare", n_clients=N, scale=0.1,
                                  seed=0)
    model = ProxyLSTM(vocab=82, seq_len=20)
    print(f"{'scenario':>14} {'strategy':>12} {'sim_time':>9} {'acc':>6} "
          f"{'cold%':>6}")
    for scenario in ("homogeneous", "two-tier", "heterogeneous"):
        for strategy in ("fedavg", "fedlesscan", "apodotiko"):
            cfg = FLConfig(n_clients=N, clients_per_round=6, rounds=8,
                           strategy=strategy, local_epochs=1, batch_size=8,
                           optimizer="sgd", lr=0.8, base_step_time=4.0,
                           round_timeout=500.0, seed=0)
            ctl = Controller(cfg, model, data, fleet(scenario))
            m = ctl.run()
            print(f"{scenario:>14} {strategy:>12} "
                  f"{m['total_time']:>8.0f}s {m['final_accuracy']:>6.3f} "
                  f"{100*m['cold_start_ratio']:>5.1f}%")


if __name__ == "__main__":
    main()
