"""Quickstart: federated training with Apodotiko on a simulated serverless
fleet, compared against FedAvg.

    PYTHONPATH=src python examples/quickstart.py

20 clients (65% 1vCPU / 25% 2vCPU / 10% GPU, the paper's mix), non-IID
Dirichlet data, real JAX local training, simulated FaaS timing (cold starts,
scale-to-zero). Prints time-to-accuracy for both strategies.
"""
import numpy as np

from repro.core.controller import Controller, FLConfig
from repro.data.synthetic import make_federated_dataset
from repro.faas.hardware import paper_fleet
from repro.models.proxy_models import ProxyCNN

N_CLIENTS = 20


def main() -> None:
    data = make_federated_dataset("speech", n_clients=N_CLIENTS, scale=0.15,
                                  seed=0)
    model = ProxyCNN(35)
    results = {}
    for strategy in ("fedavg", "apodotiko"):
        cfg = FLConfig(
            n_clients=N_CLIENTS, clients_per_round=8, rounds=12,
            strategy=strategy, concurrency_ratio=0.3,
            local_epochs=2, batch_size=5, base_step_time=1.5,
            round_timeout=400.0, seed=0)
        ctl = Controller(cfg, model, data, list(paper_fleet(N_CLIENTS)))
        m = ctl.run(progress=lambda log: print(
            f"  [{strategy}] round {log.round:2d} t={log.t_end:7.1f}s "
            f"acc={log.accuracy:.3f} agg={log.n_aggregated} "
            f"stale={log.n_stale}"))
        results[strategy] = m
        print(f"{strategy}: sim_time={m['total_time']:.0f}s "
              f"acc={m['final_accuracy']:.3f} "
              f"cold_starts={m['cold_start_ratio']:.2f} "
              f"cost=${m['total_cost_usd']:.3f}")

    # time to the accuracy FedAvg ended at
    target = results["fedavg"]["final_accuracy"]
    for s, m in results.items():
        t = next((t for t, _, a in m["history"] if a >= target), None)
        print(f"time to acc {target:.3f}: {s} = "
              f"{'n/a' if t is None else f'{t:.0f}s'}")


if __name__ == "__main__":
    main()
